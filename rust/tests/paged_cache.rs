//! Paged-KV-cache contract suite (DESIGN.md §7): block-table reads
//! stay bit-identical to the dense-era semantics, garbage redirection
//! lands in the per-row garbage block, blocks free and reuse across
//! sequences, pool exhaustion backpressures admission instead of
//! corrupting state — exercised through ALL FIVE engines — and the
//! headline batcher property: a paged pool admits more concurrent
//! sequences than the dense layout could hold in the same memory.
//! Runs in plain `cargo test` with NO artifacts.

use pard::coordinator::batcher::serve_trace_virtual;
use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::runtime::{Backend, KvStage, KV_BLOCK};
use pard::substrate::workload::{build_trace, Arrival};
use pard::Runtime;

fn cfg(rt: &Runtime, kind: EngineKind, target: &str, k: usize,
       batch: usize, kv_blocks: Option<usize>) -> EngineConfig {
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new: 12,
        shared_mask: true,
        kv_blocks,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

/// The five-engine sweep: a deliberately tight pool (4 blocks per
/// cache — one row's worth plus slack) must produce outputs identical
/// to the capacity-parity default, across three sequential prompts per
/// engine.  That only works if (a) rejected speculation is redirected
/// to the garbage block instead of clobbering live slots, and (b) a
/// finished sequence's blocks free and are reused cleanly by the next
/// admission.
#[test]
fn tight_pool_outputs_identical_across_all_five_engines() {
    let rt = Runtime::reference(7);
    let prompts = some_prompts(&rt, 3);
    for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                 EngineKind::Pard, EngineKind::Eagle] {
        let base = gen(&rt, &cfg(&rt, kind, "target-l", 8, 1, None),
                       &prompts);
        let tight = gen(&rt, &cfg(&rt, kind, "target-l", 8, 1, Some(4)),
                        &prompts);
        assert!(base.iter().all(|o| !o.is_empty()),
                "{kind:?}: default pool generated nothing");
        assert_eq!(base, tight,
                   "{kind:?}: tight paged pool changed outputs");
    }
}

/// Host fast path under an explicitly paged pool: token-identical to
/// the scalar oracle with the same pool size (the block-table read
/// path of DESIGN.md §8 under block reuse).
#[test]
fn host_paged_pool_matches_oracle_paged_pool() {
    let oracle = Runtime::reference(7);
    let host = Runtime::host(7);
    let prompts = some_prompts(&oracle, 3);
    for kind in [EngineKind::ArPlus, EngineKind::Pard] {
        let a = gen(&oracle,
                    &cfg(&oracle, kind, "target-m", 4, 2, Some(8)),
                    &prompts);
        let b = gen(&host, &cfg(&host, kind, "target-m", 4, 2, Some(8)),
                    &prompts);
        assert_eq!(a, b, "{kind:?}: host paged pool diverged");
    }
}

/// The tentpole batcher property: with the same memory budget, the
/// paged pool admits MORE concurrent sequences than the dense layout
/// could.  12 blocks/cache = 192 slots = floor(192 / S_max) = 2 dense
/// worst-case rows; short requests reserve 3 blocks each, so all 4
/// batch slots run simultaneously.
#[test]
fn paged_pool_admits_more_than_dense_budget() {
    let rt = Runtime::reference(7);
    let kv_blocks = 12usize;
    let s_max = rt.model("target-m").unwrap().cfg().s_max;
    let dense_rows = kv_blocks * KV_BLOCK / s_max;
    assert_eq!(dense_rows, 2, "12 blocks hold 2 dense worst-case rows");

    let ps = rt.prompts("gsm").unwrap().prompts;
    let trace = build_trace(&ps, 8, Arrival::Closed, 8, 3);
    let c = EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".to_string(),
        draft: default_draft(&rt.manifest, EngineKind::Pard, "target-m")
            .unwrap(),
        batch: 4,
        k: 4,
        max_new: 8,
        shared_mask: true,
        kv_blocks: Some(kv_blocks),
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    };
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    let stats = serve_trace_virtual(e.as_mut(), &trace, 1.0).unwrap();
    assert_eq!(stats.completed, 8, "all requests must complete");
    assert!(
        stats.peak_occupancy > dense_rows,
        "paged pool must beat the dense budget: peak {} vs dense {}",
        stats.peak_occupancy, dense_rows
    );
    assert_eq!(stats.peak_occupancy, 4,
               "short requests fit all four slots");
    let m = e.metrics();
    assert!(m.kv_peak_blocks > 0, "kv gauges must be recorded");
    assert!(m.kv_peak_blocks <= 2 * kv_blocks as u64,
            "pools can never exceed their configured size");
}

/// Pool-exhaustion backpressure through a real engine: a pool sized
/// for one row serializes a 2-slot batch — stalls are counted, FCFS
/// holds, everything completes, and blocks drain back to zero.
#[test]
fn engine_pool_backpressure_serializes_and_completes() {
    let rt = Runtime::reference(7);
    let ps = rt.prompts("code").unwrap().prompts;
    let trace = build_trace(&ps, 4, Arrival::Closed, 8, 5);
    let c = EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".to_string(),
        draft: default_draft(&rt.manifest, EngineKind::Pard, "target-m")
            .unwrap(),
        batch: 2,
        k: 4,
        max_new: 8,
        shared_mask: true,
        kv_blocks: Some(3),
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    };
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    let stats = serve_trace_virtual(e.as_mut(), &trace, 1.0).unwrap();
    assert_eq!(stats.completed, 4, "backpressure must not drop work");
    assert_eq!(stats.peak_occupancy, 1,
               "a one-row pool serializes the batch");
    assert!(stats.admission_stalls > 0,
            "waiting on blocks must be visible as stalls");
    assert_eq!(e.metrics().kv_blocks_in_use, 0,
               "all blocks released after the last harvest");
}

/// Raw backend-level garbage redirection: rejected speculative columns
/// land in the row's garbage block (readable at the garbage position),
/// never in a live slot, and later garbage columns overwrite earlier
/// ones.
#[test]
fn garbage_block_receives_rejected_columns() {
    let rt = Runtime::reference(7);
    let m = rt.model("target-m").unwrap();
    let mut cache = m.new_cache(1).unwrap();
    let g = cache.garbage_slot();
    let hd = m.cfg().n_heads * m.cfg().d_head;

    // prefill 3 tokens, then a verify-shaped call: pending commits
    // live at 3, two "candidates" are rejected to the garbage slot.
    let out = m.fwd(1, 3, &[0, 13, 20], &[0, 1, 2], None, &cache)
        .unwrap();
    m.commit(1, 3, &out, &[0, 1, 2], &mut cache).unwrap();
    cache.cur_len[0] = 3;
    let vout = m
        .fwd(1, 3, &[30, 31, 32], &[3, 4, 5], None, &cache)
        .unwrap();
    m.commit(1, 3, &vout, &[3, g, g], &mut cache).unwrap();

    let staged_k = match &vout.kv {
        KvStage::Host { k, .. } => k,
        #[cfg(feature = "pjrt")]
        KvStage::Pjrt { .. } => unreachable!("reference stages host KV"),
    };
    // live slot 3 holds column 0's K; the garbage block holds the LAST
    // rejected column (col 2 overwrote col 1); slots 4 and 5 untouched.
    assert_eq!(cache.host_kv(0, 0, 0, 3).unwrap(), &staged_k[..hd]);
    assert_eq!(cache.host_kv(0, 0, 0, g as usize).unwrap(),
               &staged_k[2 * hd..3 * hd]);
    assert_ne!(cache.host_kv(0, 0, 0, g as usize).unwrap(),
               &staged_k[hd..2 * hd],
               "later garbage column must win");
    assert_eq!(cache.host_kv(0, 0, 0, 4).unwrap(), vec![0f32; hd],
               "rejected candidates must not touch live slots");

    // release returns every block — including the garbage block.
    assert!(cache.blocks_in_use() >= 2);
    cache.release_row(0);
    assert_eq!(cache.blocks_in_use(), 0);
    assert!(cache.host_kv(0, 0, 0, 3).is_none(),
            "released rows map nothing");
}
