//! The serve thread (DESIGN.md §3) runs unmodified on the reference
//! backend: boot a `Server`, generate over channels, read metrics,
//! shut down — no artifacts on disk.  Request-lifecycle gates
//! (DESIGN.md §10): every request ends in exactly one typed
//! `GenOutcome` — oversized → `Rejected`, cancel → `Cancelled`,
//! deadline → `DeadlineExceeded` — and intake errors are typed
//! (`ServerClosed`), never silently dropped channels.

use std::time::Duration;

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::runtime::RuntimeSpec;
use pard::server::{GenOutcome, GenRequest, Server, ServerClosed};
use pard::Runtime;

fn cfg() -> EngineConfig {
    EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".into(),
        draft: Some("pard-main".into()),
        batch: 1,
        k: 4,
        max_new: 12,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

#[test]
fn server_thread_serves_reference_backend() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();

    // ground truth: drive the engine directly
    let mut engine = build_engine(&rt, &cfg()).unwrap();
    let direct = generate(engine.as_mut(), &[prompt.clone()], 12)
        .unwrap()
        .remove(0);

    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let resp = server
        .generate(GenRequest::new(1, prompt.clone(), 12))
        .unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens, direct,
               "server thread must produce the same greedy stream");
    assert!(resp.latency_s >= 0.0);

    let m = server.metrics().unwrap();
    assert!(m.generated > 0);

    // a second request exercises slot reuse inside the server loop
    let resp2 = server.generate(GenRequest::new(2, prompt, 12)).unwrap();
    assert_eq!(resp2.tokens, direct);

    assert!(server.fatal_error().is_none(), "healthy engine thread");
    server.shutdown().unwrap();
}

/// Concurrent requests share the batched loop: a 2-slot server takes
/// several outstanding submissions at once, batches them through
/// shared decode iterations, and every response matches the directly
/// generated greedy stream for its prompt.
#[test]
fn server_batches_concurrent_requests() {
    let rt = Runtime::reference(7);
    let prompts: Vec<Vec<i32>> = rt
        .prompts("code")
        .unwrap()
        .take(4)
        .into_iter()
        .map(|p| p.prompt)
        .collect();

    // ground truth: the same engine config driven directly
    let mut c = cfg();
    c.batch = 2;
    let mut engine = build_engine(&rt, &c).unwrap();
    let direct = generate(engine.as_mut(), &prompts, c.max_new).unwrap();

    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, c).unwrap();
    // submit everything before reading any response: all four are
    // outstanding together, so they must flow through the batched path
    let handles: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            server
                .submit(GenRequest::new(i as u64, p.clone(), 12))
                .unwrap()
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        match h.recv().unwrap() {
            GenOutcome::Completed(resp) => {
                assert_eq!(resp.id, i as u64);
                assert_eq!(resp.tokens, direct[i],
                           "request {i}: batched serving changed the \
                            stream");
            }
            other => panic!("request {i}: expected Completed, got \
                             {other:?}"),
        }
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.requests, 4);
    server.shutdown().unwrap();
}

/// An oversized request (reservation bigger than the whole KV pool)
/// must get a typed `Rejected` outcome — not a dropped channel —
/// without killing the engine thread: later, smaller requests still
/// serve.
#[test]
fn oversized_request_rejected_without_killing_server() {
    let mut c = cfg();
    c.kv_blocks = Some(2); // minimum pool: 1 live + 1 garbage block
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, c).unwrap();
    // needs ceil((5 + 64 + 4 + 2)/16) + 1 = 6 blocks > 2: impossible
    let h = server
        .submit(GenRequest::new(1, vec![0, 13, 20, 21, 22], 64))
        .unwrap();
    match h.recv().unwrap() {
        GenOutcome::Rejected { id, reason } => {
            assert_eq!(id, 1);
            assert!(reason.contains("--kv-blocks"),
                    "rejection must say how to fix it: {reason}");
        }
        other => panic!("expected Rejected, got {other:?}"),
    }
    // a small request still fits the pool and completes
    let resp = server
        .generate(GenRequest::new(2, vec![0, 13, 20], 4))
        .unwrap();
    assert_eq!(resp.id, 2);
    assert!(!resp.tokens.is_empty(), "server must keep serving");
    server.shutdown().unwrap();
}

/// `shutdown` stops intake but drains what was already submitted:
/// requests queued before the shutdown message still complete, and a
/// submit AFTER shutdown gets the typed `ServerClosed` error.
#[test]
fn shutdown_drains_queued_then_closes_intake() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let handles: Vec<_> = (0..3)
        .map(|i| {
            server.submit(GenRequest::new(i, prompt.clone(), 8)).unwrap()
        })
        .collect();
    // Shutdown queues BEHIND the three Generate messages (one mpsc
    // channel), so intake closes only after they are all in.
    server.shutdown().unwrap();
    for (i, h) in handles.into_iter().enumerate() {
        match h.recv().unwrap() {
            GenOutcome::Completed(resp) => {
                assert_eq!(resp.id, i as u64);
                assert!(!resp.tokens.is_empty());
            }
            other => panic!("queued request {i} must complete through \
                             shutdown, got {other:?}"),
        }
    }
    match server.submit(GenRequest::new(9, prompt, 8)) {
        Err(ServerClosed) => {}
        Ok(_) => panic!("submit after shutdown must fail typed"),
    }
    // shutdown is idempotent
    server.shutdown().unwrap();
}

/// Cancelling a queued request yields a typed `Cancelled` outcome and
/// counts in the metrics; the in-flight request ahead of it is
/// untouched.
#[test]
fn cancel_queued_request_yields_typed_outcome() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();
    // batch 1: the second submission stays queued while the first
    // decodes, so the cancel deterministically lands on a queued row.
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let h1 = server
        .submit(GenRequest::new(1, prompt.clone(), 12))
        .unwrap();
    let h2 = server.submit(GenRequest::new(2, prompt, 12)).unwrap();
    h2.cancel();
    match h2.recv().unwrap() {
        GenOutcome::Cancelled { id } => assert_eq!(id, 2),
        other => panic!("expected Cancelled, got {other:?}"),
    }
    match h1.recv().unwrap() {
        GenOutcome::Completed(resp) => {
            assert_eq!(resp.id, 1);
            assert!(!resp.tokens.is_empty(),
                    "the live request must be unaffected");
        }
        other => panic!("expected Completed, got {other:?}"),
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.cancelled, 1);
    server.shutdown().unwrap();
}

/// A request whose deadline has already passed is dropped with a typed
/// `DeadlineExceeded` outcome — and its KV blocks never stay pinned.
#[test]
fn expired_deadline_yields_typed_outcome() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let mut req = GenRequest::new(1, prompt.clone(), 12);
    req.deadline = Some(Duration::ZERO); // expired on arrival
    let h = server.submit(req).unwrap();
    match h.recv().unwrap() {
        GenOutcome::DeadlineExceeded { id } => assert_eq!(id, 1),
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.deadline_exceeded, 1);
    // the engine keeps serving afterwards
    let resp = server.generate(GenRequest::new(2, prompt, 8)).unwrap();
    assert!(!resp.tokens.is_empty());
    server.shutdown().unwrap();
}

/// A malformed request (empty prompt — prefill has no suffix to run)
/// panics or errors inside `Engine::admit`; the serve loop must catch
/// it and degrade exactly that request to a typed `Failed` outcome
/// (audit rule R1, DESIGN.md §10).  The engine thread survives: a
/// well-formed request submitted afterwards completes normally.
#[test]
fn malformed_request_fails_typed_without_killing_server() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();
    let mut server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let h = server.submit(GenRequest::new(1, Vec::new(), 8)).unwrap();
    match h.recv().unwrap() {
        GenOutcome::Failed { id, reason } => {
            assert_eq!(id, 1);
            assert!(reason.contains("admission"),
                    "failure must name the phase: {reason}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.rows_failed, 1, "the failure must be counted");
    // the slot was torn down cleanly: the engine keeps serving
    let resp = server.generate(GenRequest::new(2, prompt, 8)).unwrap();
    assert_eq!(resp.id, 2);
    assert!(!resp.tokens.is_empty(), "server must keep serving");
    assert!(server.fatal_error().is_none(),
            "a per-request failure is not a fatal serve-loop error");
    server.shutdown().unwrap();
}

#[test]
fn runtime_spec_reference_opens_without_artifacts() {
    let rt = RuntimeSpec::Reference { seed: 3 }.open().unwrap();
    assert!(rt.is_reference());
    assert_eq!(rt.manifest.main_pard, "pard-main");
    assert!(rt.model("target-l").is_ok());
    assert!(rt.model("no-such-model").is_err());
}
