//! The serve thread (DESIGN.md §3) runs unmodified on the reference
//! backend: boot a `Server`, generate over channels, read metrics,
//! shut down — no artifacts on disk.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::runtime::RuntimeSpec;
use pard::server::{GenRequest, Server};
use pard::Runtime;

fn cfg() -> EngineConfig {
    EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".into(),
        draft: Some("pard-main".into()),
        batch: 1,
        k: 4,
        max_new: 12,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

#[test]
fn server_thread_serves_reference_backend() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();

    // ground truth: drive the engine directly
    let mut engine = build_engine(&rt, &cfg()).unwrap();
    let direct = generate(engine.as_mut(), &[prompt.clone()], 12)
        .unwrap()
        .remove(0);

    let server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let resp = server
        .generate(GenRequest { id: 1, prompt: prompt.clone(), max_new: 12 })
        .unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens, direct,
               "server thread must produce the same greedy stream");
    assert!(resp.latency_s >= 0.0);

    let m = server.metrics().unwrap();
    assert!(m.generated > 0);

    // a second request exercises slot reuse inside the server loop
    let resp2 = server
        .generate(GenRequest { id: 2, prompt, max_new: 12 })
        .unwrap();
    assert_eq!(resp2.tokens, direct);

    server.shutdown().unwrap();
}

/// Concurrent requests share the batched loop: a 2-slot server takes
/// several outstanding submissions at once, batches them through
/// shared decode iterations, and every response matches the directly
/// generated greedy stream for its prompt.
#[test]
fn server_batches_concurrent_requests() {
    let rt = Runtime::reference(7);
    let prompts: Vec<Vec<i32>> = rt
        .prompts("code")
        .unwrap()
        .take(4)
        .into_iter()
        .map(|p| p.prompt)
        .collect();

    // ground truth: the same engine config driven directly
    let mut c = cfg();
    c.batch = 2;
    let mut engine = build_engine(&rt, &c).unwrap();
    let direct = generate(engine.as_mut(), &prompts, c.max_new).unwrap();

    let server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, c).unwrap();
    // submit everything before reading any response: all four are
    // outstanding together, so they must flow through the batched path
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            server
                .submit(GenRequest {
                    id: i as u64,
                    prompt: p.clone(),
                    max_new: 12,
                })
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.tokens, direct[i],
                   "request {i}: batched serving changed the stream");
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.requests, 4);
    server.shutdown().unwrap();
}

/// An oversized request (reservation bigger than the whole KV pool)
/// must fail ITS caller — the reply channel drops — without killing
/// the engine thread: later, smaller requests still serve.
#[test]
fn oversized_request_rejected_without_killing_server() {
    let mut c = cfg();
    c.kv_blocks = Some(2); // minimum pool: 1 live + 1 garbage block
    let server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, c).unwrap();
    // needs ceil((5 + 64 + 4 + 2)/16) + 1 = 6 blocks > 2: impossible
    let rx = server
        .submit(GenRequest { id: 1, prompt: vec![0, 13, 20, 21, 22],
                             max_new: 64 })
        .unwrap();
    assert!(rx.recv().is_err(),
            "oversized request must surface an error to its caller");
    // a small request still fits the pool and completes
    let resp = server
        .generate(GenRequest { id: 2, prompt: vec![0, 13, 20],
                               max_new: 4 })
        .unwrap();
    assert_eq!(resp.id, 2);
    assert!(!resp.tokens.is_empty(), "server must keep serving");
    server.shutdown().unwrap();
}

#[test]
fn runtime_spec_reference_opens_without_artifacts() {
    let rt = RuntimeSpec::Reference { seed: 3 }.open().unwrap();
    assert!(rt.is_reference());
    assert_eq!(rt.manifest.main_pard, "pard-main");
    assert!(rt.model("target-l").is_ok());
    assert!(rt.model("no-such-model").is_err());
}
