//! The serve thread (DESIGN.md §3) runs unmodified on the reference
//! backend: boot a `Server`, generate over channels, read metrics,
//! shut down — no artifacts on disk.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::runtime::RuntimeSpec;
use pard::server::{GenRequest, Server};
use pard::Runtime;

fn cfg() -> EngineConfig {
    EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".into(),
        draft: Some("pard-main".into()),
        batch: 1,
        k: 4,
        max_new: 12,
        shared_mask: true,
    }
}

#[test]
fn server_thread_serves_reference_backend() {
    let rt = Runtime::reference(7);
    let prompt = rt.prompts("code").unwrap().prompts[0].prompt.clone();

    // ground truth: drive the engine directly
    let mut engine = build_engine(&rt, &cfg()).unwrap();
    let direct = generate(engine.as_mut(), &[prompt.clone()], 12)
        .unwrap()
        .remove(0);

    let server =
        Server::start(RuntimeSpec::Reference { seed: 7 }, cfg()).unwrap();
    let resp = server
        .generate(GenRequest { id: 1, prompt: prompt.clone(), max_new: 12 })
        .unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens, direct,
               "server thread must produce the same greedy stream");
    assert!(resp.latency_s >= 0.0);

    let m = server.metrics().unwrap();
    assert!(m.generated > 0);

    // a second request exercises slot reuse inside the server loop
    let resp2 = server
        .generate(GenRequest { id: 2, prompt, max_new: 12 })
        .unwrap();
    assert_eq!(resp2.tokens, direct);

    server.shutdown().unwrap();
}

#[test]
fn runtime_spec_reference_opens_without_artifacts() {
    let rt = RuntimeSpec::Reference { seed: 3 }.open().unwrap();
    assert!(rt.is_reference());
    assert_eq!(rt.manifest.main_pard, "pard-main");
    assert!(rt.model("target-l").is_ok());
    assert!(rt.model("no-such-model").is_err());
}
