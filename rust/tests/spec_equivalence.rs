//! Property tests on the coordinator's speculative-decoding core —
//! runtime-free (a deterministic hash LM stands in for the target), so
//! they exercise the acceptance/iteration logic in isolation.
//!
//! Headline property: for ANY draft proposal stream, greedy speculative
//! decoding commits EXACTLY the sequence plain greedy AR would produce —
//! the paper's "no loss of performance" claim reduced to coordinator
//! logic.

use pard::coordinator::engines::greedy_accept;
use pard::substrate::prop::Cases;
use pard::substrate::rng::Rng;

/// Deterministic toy LM: next token is a hash of the last 3 tokens.
fn oracle_next(prefix: &[i32], vocab: i32) -> i32 {
    let mut h: i64 = 0x9E37;
    for &t in prefix.iter().rev().take(3) {
        h = h.wrapping_mul(31).wrapping_add(t as i64 + 7);
    }
    (h.rem_euclid(vocab as i64)) as i32
}

fn ar_decode(prompt: &[i32], steps: usize, vocab: i32) -> Vec<i32> {
    let mut stream = prompt.to_vec();
    for _ in 0..steps {
        stream.push(oracle_next(&stream, vocab));
    }
    stream[prompt.len()..].to_vec()
}

/// Speculative decode against the same oracle, with an arbitrary
/// (possibly adversarial) draft.
fn spec_decode(prompt: &[i32], steps: usize, vocab: i32, k: usize,
               rng: &mut Rng, draft_quality: f64) -> (Vec<i32>, usize) {
    let mut stream = prompt.to_vec();
    let target_total = steps;
    let mut iters = 0usize;
    while stream.len() - prompt.len() < target_total {
        iters += 1;
        // draft k candidates: with prob draft_quality each matches the
        // oracle continuation, else random junk
        let mut cands = Vec::with_capacity(k);
        let mut sim = stream.clone();
        for _ in 0..k {
            let truth = oracle_next(&sim, vocab);
            let c = if rng.chance(draft_quality) {
                truth
            } else {
                rng.below(vocab as usize) as i32
            };
            cands.push(c);
            sim.push(c);
        }
        // verify: preds[j] = oracle's next token given stream + accepted
        // candidate prefix (what the batched verify pass computes)
        let mut preds = Vec::with_capacity(k + 1);
        let mut ctx = stream.clone();
        preds.push(oracle_next(&ctx, vocab));
        for &c in &cands {
            ctx.push(c);
            preds.push(oracle_next(&ctx, vocab));
        }
        let (_a, committed) = greedy_accept(&cands, &preds);
        stream.extend_from_slice(&committed);
    }
    stream.truncate(prompt.len() + target_total);
    (stream[prompt.len()..].to_vec(), iters)
}

#[test]
fn speculative_equals_ar_for_any_draft_quality() {
    Cases::new(200).check("spec==ar", |rng| {
        let vocab = 64;
        let plen = 1 + rng.below(8);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(vocab) as i32).collect();
        let steps = 8 + rng.below(40);
        let k = 1 + rng.below(12);
        let q = rng.f64(); // draft quality 0..1, incl adversarial
        let ar = ar_decode(&prompt, steps, vocab as i32);
        let (spec, _) =
            spec_decode(&prompt, steps, vocab as i32, k, rng, q);
        assert_eq!(ar, spec, "lossless property violated (k={k}, q={q})");
    });
}

#[test]
fn perfect_draft_commits_k_plus_one_per_iter() {
    let mut rng = Rng::new(1);
    let prompt = vec![3, 5];
    let steps = 33;
    let k = 8;
    let (out, iters) = spec_decode(&prompt, steps, 64, k, &mut rng, 1.0);
    assert_eq!(out.len(), steps);
    // perfect acceptance: ceil(steps / (k+1)) iterations
    assert_eq!(iters, steps.div_ceil(k + 1));
}

#[test]
fn hopeless_draft_still_makes_progress() {
    Cases::new(32).check("one-token-per-iter-min", |rng| {
        let prompt = vec![1];
        let steps = 12;
        let (out, iters) = spec_decode(&prompt, steps, 64, 4, rng, 0.0);
        assert_eq!(out.len(), steps);
        // worst case: exactly one (the correction) per iteration
        assert!(iters <= steps);
    });
}

#[test]
fn greedy_accept_edges() {
    // empty draft: pure correction
    let (a, c) = greedy_accept(&[], &[9]);
    assert_eq!((a, c), (0, vec![9]));
    // full accept
    let (a, c) = greedy_accept(&[1, 2], &[1, 2, 7]);
    assert_eq!((a, c), (2, vec![1, 2, 7]));
    // reject at 0
    let (a, c) = greedy_accept(&[5], &[6, 0]);
    assert_eq!((a, c), (0, vec![6]));
    // partial
    let (a, c) = greedy_accept(&[5, 5, 5], &[5, 4, 1, 2]);
    assert_eq!((a, c), (1, vec![5, 4]));
}

#[test]
fn committed_never_exceeds_k_plus_one() {
    Cases::new(200).check("commit-bound", |rng| {
        let k = 1 + rng.below(16);
        let vocab = 32;
        let cands: Vec<i32> =
            (0..k).map(|_| rng.below(vocab) as i32).collect();
        let preds: Vec<i32> =
            (0..=k).map(|_| rng.below(vocab) as i32).collect();
        let (a, c) = greedy_accept(&cands, &preds);
        assert!(a <= k);
        assert_eq!(c.len(), a + 1);
        // committed prefix must equal the accepted candidates
        assert_eq!(&c[..a], &cands[..a]);
        // the correction is the target's prediction at the break point
        assert_eq!(c[a], preds[a]);
    });
}
