//! Runtime-layer integration: the fwd/commit executables against the
//! DESIGN.md §7 cache contract.  Built only with the `pjrt` feature
//! and gated on artifacts/.
#![cfg(feature = "pjrt")]

use std::path::Path;

use pard::coordinator::sampling::argmax;
use pard::runtime::Backend;
use pard::Runtime;

fn runtime() -> Option<Runtime> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing");
        return None;
    }
    Some(Runtime::load(p).expect("runtime loads"))
}

/// Drive raw fwd/commit to decode greedily; used as the reference body.
fn raw_decode(rt: &Runtime, model: &str, prompt: &[i32], steps: usize)
              -> Vec<i32> {
    let m = rt.model(model).unwrap();
    let mut cache = m.new_cache(1).unwrap();
    let vocab = m.cfg().vocab;
    let t = m.pick_t(1, prompt.len()).unwrap();
    let g = cache.garbage_slot();
    let mut tokens = vec![rt.manifest.pad; t];
    let mut pos = vec![g; t];
    for (i, &tk) in prompt.iter().enumerate() {
        tokens[i] = tk;
        pos[i] = i as i32;
    }
    let out = m.fwd(1, t, &tokens, &pos, None, &cache).unwrap();
    m.commit(1, t, &out, &pos, &mut cache).unwrap();
    cache.cur_len[0] = prompt.len() as u32;
    let last = prompt.len() - 1;
    let mut next = argmax(&out.logits[last * vocab..(last + 1) * vocab]);
    let mut gen = vec![next];
    for _ in 1..steps {
        let p = cache.cur_len[0] as i32;
        let out = m.fwd(1, 1, &[next], &[p], None, &cache).unwrap();
        m.commit(1, 1, &out, &[p], &mut cache).unwrap();
        cache.cur_len[0] += 1;
        next = argmax(&out.logits[..vocab]);
        gen.push(next);
    }
    gen
}

#[test]
fn bucket_padding_does_not_change_logits() {
    // The same prefill through two different T buckets (pads parked at
    // the garbage slot) must produce identical greedy continuations.
    let Some(rt) = runtime() else { return };
    let m = rt.model("draft-s").unwrap();
    let prompt: Vec<i32> =
        rt.prompts("code").unwrap().prompts[0].prompt.clone();
    let vocab = m.cfg().vocab;
    let mut firsts = Vec::new();
    for t in [m.pick_t(1, prompt.len()).unwrap(), 32, 64] {
        let cache = m.new_cache(1).unwrap();
        let g = cache.garbage_slot();
        let mut tokens = vec![rt.manifest.pad; t];
        let mut pos = vec![g; t];
        for (i, &tk) in prompt.iter().enumerate() {
            tokens[i] = tk;
            pos[i] = i as i32;
        }
        let out = m.fwd(1, t, &tokens, &pos, None, &cache).unwrap();
        let last = prompt.len() - 1;
        firsts.push(argmax(&out.logits[last * vocab..(last + 1) * vocab]));
    }
    assert!(firsts.windows(2).all(|w| w[0] == w[1]),
            "bucket choice changed the argmax: {firsts:?}");
}

#[test]
fn stale_speculative_entries_are_unreachable() {
    // Write junk KV beyond cur_len (a rejected speculation), then decode
    // normally: outputs must match a never-polluted trajectory.
    let Some(rt) = runtime() else { return };
    let m = rt.model("target-m").unwrap();
    let prompt: Vec<i32> =
        rt.prompts("gsm").unwrap().prompts[0].prompt.clone();
    let clean = raw_decode(&rt, "target-m", &prompt, 8);

    // polluted run: same prefill, then junk writes at future positions
    let mut cache = m.new_cache(1).unwrap();
    let vocab = m.cfg().vocab;
    let t = m.pick_t(1, prompt.len()).unwrap();
    let g = cache.garbage_slot();
    let mut tokens = vec![rt.manifest.pad; t];
    let mut pos = vec![g; t];
    for (i, &tk) in prompt.iter().enumerate() {
        tokens[i] = tk;
        pos[i] = i as i32;
    }
    let out = m.fwd(1, t, &tokens, &pos, None, &cache).unwrap();
    m.commit(1, t, &out, &pos, &mut cache).unwrap();
    cache.cur_len[0] = prompt.len() as u32;
    let last = prompt.len() - 1;
    let mut next = argmax(&out.logits[last * vocab..(last + 1) * vocab]);
    // junk speculation at positions len..len+3, never "accepted"
    let jt = 4;
    let jp: Vec<i32> =
        (0..jt).map(|i| (prompt.len() + i) as i32).collect();
    let junk = vec![rt.manifest.mask; jt];
    let jout = m.fwd(1, jt, &junk, &jp, None, &cache).unwrap();
    m.commit(1, jt, &jout, &jp, &mut cache).unwrap(); // junk committed!
    // …but cur_len was never advanced, so the decode below overwrites
    // those slots before they are attendable.
    let mut gen = vec![next];
    for _ in 1..8 {
        let p = cache.cur_len[0] as i32;
        let out = m.fwd(1, 1, &[next], &[p], None, &cache).unwrap();
        m.commit(1, 1, &out, &[p], &mut cache).unwrap();
        cache.cur_len[0] += 1;
        next = argmax(&out.logits[..vocab]);
        gen.push(next);
    }
    assert_eq!(clean, gen, "stale speculative KV leaked into attention");
}

#[test]
fn pick_t_policy() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("draft-s").unwrap();
    assert_eq!(m.pick_t(1, 1).unwrap(), 1);
    // T=10/12 buckets exist for the §Perf verify/draft tightening
    assert_eq!(m.pick_t(1, 9).unwrap(), 10);
    assert_eq!(m.pick_t(1, 11).unwrap(), 12);
    assert_eq!(m.pick_t(1, 13).unwrap(), 16);
    assert_eq!(m.pick_t(1, 17).unwrap(), 24);
    assert!(m.pick_t(1, 65).is_err());
}

#[test]
fn weights_load_for_every_model() {
    let Some(rt) = runtime() else { return };
    for name in rt.manifest.models.keys() {
        let m = rt.model(name).expect("model loads");
        assert!(m.n_params() > 0);
    }
}

#[test]
fn pard_variant_shares_architecture_with_draft() {
    let Some(rt) = runtime() else { return };
    let base = rt.model("draft-s").unwrap();
    let pard = rt.model(&rt.manifest.main_pard).unwrap();
    assert_eq!(base.cfg().d_model, pard.cfg().d_model);
    assert_eq!(base.cfg().n_layers, pard.cfg().n_layers);
    // identical architecture but different weights => different outputs
    let prompt: Vec<i32> =
        rt.prompts("code").unwrap().prompts[0].prompt.clone();
    let a = raw_decode(&rt, "draft-s", &prompt, 6);
    let b = raw_decode(&rt, &rt.manifest.main_pard, &prompt, 6);
    assert!(a != b || a.is_empty() == false);
}
