//! Engine-equivalence suite on the deterministic reference backend —
//! runs in plain `cargo test` with NO Python/XLA artifacts.
//!
//! The central assertion is the LOSSLESS property of draft-then-verify
//! (paper §2 / Eq. 3-4; the invariant ParallelSpec and the SD survey
//! both rest on): VSD/PARD/EAGLE greedy outputs are token-identical to
//! AR+ greedy outputs for every prompt, at any K and batch size, and
//! AR+ itself is identical to uncached full-recompute AR — which
//! certifies the whole (tokens, pos, commit_pos) cache machinery, not
//! just the acceptance arithmetic.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::coordinator::sampling::argmax;
use pard::runtime::Backend;
use pard::Runtime;

fn rt() -> Runtime {
    Runtime::reference(7)
}

fn cfg(rt: &Runtime, kind: EngineKind, target: &str, k: usize,
       batch: usize) -> EngineConfig {
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new: 20,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

/// The acceptance-criterion sweep: every speculative engine must
/// reproduce AR+ greedy outputs exactly, for K ∈ {2,4,8} × batch ∈
/// {1,4}.
#[test]
fn lossless_across_k_and_batch() {
    let rt = rt();
    let prompts = some_prompts(&rt, 4);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-l", 8, 1),
                   &prompts);
    assert!(base.iter().all(|o| !o.is_empty()), "base generated nothing");
    for kind in [EngineKind::Vsd, EngineKind::Pard, EngineKind::Eagle] {
        for k in [2usize, 4, 8] {
            for batch in [1usize, 4] {
                let out = gen(&rt, &cfg(&rt, kind, "target-l", k, batch),
                              &prompts);
                assert_eq!(
                    base, out,
                    "{kind:?} k={k} batch={batch} diverged from AR+"
                );
            }
        }
    }
}

#[test]
fn uncached_ar_matches_cached_ar_plus() {
    // AR (full recompute) and AR+ (KV-cached) are numerically different
    // computations of the SAME function — greedy outputs must agree,
    // certifying the cache scatter/position-mask contract end to end.
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    let a = gen(&rt, &cfg(&rt, EngineKind::Ar, "target-m", 8, 1),
                &prompts);
    let b = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-m", 8, 1),
                &prompts);
    assert_eq!(a, b, "KV-cached decode must equal full recompute");
}

#[test]
fn pard_extrapolates_k_infer_beyond_k_train() {
    // K_train = 8 for pard-main; shared mask ids let K_infer exceed it
    // (paper §4.3) — and losslessness must hold regardless.
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-m", 8, 1),
                   &prompts);
    for k in [1usize, 12, 16] {
        let out = gen(&rt, &cfg(&rt, EngineKind::Pard, "target-m", k, 1),
                      &prompts);
        assert_eq!(base, out, "PARD K_infer={k} must stay lossless");
    }
}

#[test]
fn pard_distinct_mask_ablation_stays_lossless() {
    // Distinct mask ids (§4.3 ablation) clamp offsets past the trained
    // range to the last trained id — output must still equal AR+.
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-m", 8, 1),
                   &prompts);
    for k in [8usize, 12] {
        let mut c = cfg(&rt, EngineKind::Pard, "target-m", k, 1);
        c.shared_mask = false;
        let out = gen(&rt, &c, &prompts);
        assert_eq!(base, out, "distinct-mask PARD K={k} diverged");
    }
}

#[test]
fn slot_reuse_is_clean() {
    // Re-admitting new prompts into a used slot must behave like a
    // fresh engine (stale cache content is unreachable by construction).
    let rt = rt();
    let prompts = some_prompts(&rt, 5);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 8, 1);
    let reused = gen(&rt, &c, &prompts);
    for (i, p) in prompts.iter().enumerate() {
        let fresh = gen(&rt, &c, std::slice::from_ref(p));
        assert_eq!(fresh[0], reused[i], "slot reuse leaked state at {i}");
    }
}

#[test]
fn batch_size_does_not_change_outputs() {
    let rt = rt();
    let prompts = some_prompts(&rt, 6);
    let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-l", 8, 1),
                   &prompts);
    for bs in [2usize, 4] {
        let out = gen(&rt, &cfg(&rt, EngineKind::ArPlus, "target-l", 8,
                                bs), &prompts);
        assert_eq!(base, out, "AR+ batch={bs} changed outputs");
    }
}

#[test]
fn target_independence_one_draft_many_targets() {
    // ONE PARD draft serves the whole family with no retraining — and
    // stays lossless on each member (paper Table 2).
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    for target in ["draft-s", "target-m", "target-l", "target-xl"] {
        let base = gen(&rt, &cfg(&rt, EngineKind::ArPlus, target, 8, 1),
                       &prompts);
        let out = gen(&rt, &cfg(&rt, EngineKind::Pard, target, 8, 1),
                      &prompts);
        assert_eq!(base, out, "PARD not lossless on {target}");
    }
}

#[test]
fn self_draft_vsd_accepts_every_candidate() {
    // draft == target weights ⇒ every candidate matches the verify
    // prediction bit-for-bit ⇒ acceptance is exactly 1.0 and each
    // iteration commits K+1 tokens.  Exercises the accept-all commit
    // path deterministically.
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let mut c = cfg(&rt, EngineKind::Vsd, "draft-s", 4, 1);
    c.draft = Some("draft-s".to_string());
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, c.max_new).unwrap();
    let m = e.metrics();
    assert!(m.generated > 0);
    assert_eq!(m.k_alpha(4), 1.0, "self-draft must accept everything");
    assert!(m.tokens_per_iter() > 3.0,
            "accept-all should commit ~K+1/iter, got {}",
            m.tokens_per_iter());
}

#[test]
fn pard_first_candidate_always_accepted_with_shared_weights() {
    // pard-main shares draft-s weights, so when it also serves AS the
    // target, candidate 0 (computed from reals only, no masks) always
    // matches the verify prediction.
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let c = cfg(&rt, EngineKind::Pard, "draft-s", 8, 1);
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, c.max_new).unwrap();
    let m = e.metrics();
    assert_eq!(m.pos_alpha(0), 1.0,
               "c_0 must always be accepted when draft == target");
}

#[test]
fn pard_drafts_in_one_pass_vsd_in_k() {
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 8, 1);
    let mut e = build_engine(&rt, &c).unwrap();
    generate(e.as_mut(), &prompts, 16).unwrap();
    let m = e.metrics();
    assert!(m.iterations > 0);
    assert_eq!(m.draft_passes, m.iterations,
               "PARD must draft in ONE pass per iteration");

    let c = cfg(&rt, EngineKind::Vsd, "target-m", 8, 1);
    let mut e = build_engine(&rt, &c).unwrap();
    generate(e.as_mut(), &prompts, 16).unwrap();
    let m = e.metrics();
    assert_eq!(m.draft_passes, 8 * m.iterations,
               "VSD pays K draft passes per iteration");
}

#[test]
fn same_seed_same_outputs_different_seed_different_weights() {
    let a = Runtime::reference(7);
    let b = Runtime::reference(7);
    let prompts = some_prompts(&a, 2);
    let oa = gen(&a, &cfg(&a, EngineKind::Pard, "target-m", 8, 1),
                 &prompts);
    let ob = gen(&b, &cfg(&b, EngineKind::Pard, "target-m", 8, 1),
                 &prompts);
    assert_eq!(oa, ob, "reference backend must be run-to-run exact");

    let c = Runtime::reference(8);
    let ma = a.model("target-m").unwrap();
    let mc = c.model("target-m").unwrap();
    let ca = ma.new_cache(1).unwrap();
    let cc = mc.new_cache(1).unwrap();
    let la = ma.fwd(1, 1, &[13], &[0], None, &ca).unwrap().logits;
    let lc = mc.fwd(1, 1, &[13], &[0], None, &cc).unwrap().logits;
    assert_ne!(la, lc, "different seeds must give different weights");
}

/// Raw backend-level port of the garbage-slot contract test: junk KV
/// committed beyond `cur_len` must never influence later decoding.
#[test]
fn stale_speculative_entries_are_unreachable() {
    let rt = rt();
    let m = rt.model("target-m").unwrap();
    let vocab = m.cfg().vocab;
    let prompt = some_prompts(&rt, 1).remove(0);

    let decode = |pollute: bool| -> Vec<i32> {
        let mut cache = m.new_cache(1).unwrap();
        let t = prompt.len();
        let pos: Vec<i32> = (0..t as i32).collect();
        let out = m.fwd(1, t, &prompt, &pos, None, &cache).unwrap();
        m.commit(1, t, &out, &pos, &mut cache).unwrap();
        cache.cur_len[0] = t as u32;
        let last = t - 1;
        let mut next =
            argmax(&out.logits[last * vocab..(last + 1) * vocab]);
        if pollute {
            // junk speculation at positions t..t+3, never "accepted":
            // committed, but cur_len is not advanced, so the slots are
            // rewritten before they become attendable.
            let jp: Vec<i32> = (0..4).map(|i| (t + i) as i32).collect();
            let junk = vec![rt.manifest.mask; 4];
            let jout = m.fwd(1, 4, &junk, &jp, None, &cache).unwrap();
            m.commit(1, 4, &jout, &jp, &mut cache).unwrap();
        }
        let mut out_toks = vec![next];
        for _ in 1..8 {
            let p = cache.cur_len[0] as i32;
            let o = m.fwd(1, 1, &[next], &[p], None, &cache).unwrap();
            m.commit(1, 1, &o, &[p], &mut cache).unwrap();
            cache.cur_len[0] += 1;
            next = argmax(&o.logits[..vocab]);
            out_toks.push(next);
        }
        out_toks
    };

    assert_eq!(decode(false), decode(true),
               "stale speculative KV leaked into attention");
}

#[test]
fn continuous_batching_serves_trace_on_reference() {
    use pard::coordinator::batcher::serve_trace;
    use pard::substrate::workload::{build_trace, Arrival};
    let rt = rt();
    let ps = rt.prompts("gsm").unwrap().prompts;
    let trace = build_trace(&ps, 9, Arrival::Closed, 16, 3);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 8, 4);
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    let stats = serve_trace(e.as_mut(), &trace).unwrap();
    assert_eq!(stats.completed, 9, "all requests must complete");
    assert!(stats.generated > 0);
    assert!(stats.throughput_tps > 0.0);
    assert!(stats.mean_occupancy > 1.0,
            "batcher should keep multiple slots busy");
}

#[test]
fn eos_and_max_new_respected() {
    let rt = rt();
    let eos = rt.manifest.eos;
    let prompts = some_prompts(&rt, 4);
    let mut c = cfg(&rt, EngineKind::Pard, "target-m", 8, 1);
    c.max_new = 10;
    let outs = gen(&rt, &c, &prompts);
    for o in outs {
        assert!(o.len() <= 10);
        if let Some(i) = o.iter().position(|&t| t == eos) {
            assert_eq!(i + 1, o.len(), "tokens after EOS");
        }
    }
}
