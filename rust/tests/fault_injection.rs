//! Chaos determinism gate (DESIGN.md §10): a seeded `FaultPlan`
//! injecting draft faults, target faults, transient pool exhaustion,
//! and one scripted worker panic over a 16-request trace on the
//! work-costed virtual clock must leave the serving loop standing,
//! with
//!
//! * (a) the engine surviving every incident (no `Err`, no unwound
//!   serve),
//! * (b) every NON-faulted request's token stream bit-identical to
//!   the fault-free run — greedy AND sampled,
//! * (c) faulted rows ending in typed outcomes with their KV blocks
//!   back in the pool (`kv_blocks_in_use == 0` at drain), and
//! * (d) the robustness counters matching the plan's schedule
//!   EXACTLY, computed by replaying a clone of the plan.
//!
//! The replay works because the batcher draws exactly one `FaultSet`
//! per iteration that steps an already-live batch — the schedule is a
//! pure function of (specs, draw index), so cloning the plan and
//! re-drawing `plan.iteration()` sets predicts every counter.
//! Mirrored in python/refsim/hostsim.py and gated by ci.sh.

use pard::coordinator::batcher::{serve_trace_virtual_costed,
                                 serve_trace_virtual_costed_with_faults,
                                 RequestOutcome};
use pard::coordinator::engines::{build_engine, EngineConfig, EngineKind,
                                 SamplingCfg};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::substrate::fault::{FaultKind, FaultPlan, FaultSpec,
                             MAX_TARGET_RETRIES};
use pard::substrate::workload::{build_trace, Arrival};
use pard::Runtime;

const N_REQ: usize = 16;
const MAX_NEW: usize = 16;
const PASS_S: f64 = 1.0;
const COL_S: f64 = 0.05;

fn cfg(rt: &Runtime, sampling: Option<SamplingCfg>) -> EngineConfig {
    EngineConfig {
        kind: EngineKind::Pard,
        target: "target-m".to_string(),
        draft: default_draft(&rt.manifest, EngineKind::Pard, "target-m")
            .unwrap(),
        batch: 4,
        k: 4,
        max_new: MAX_NEW,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling,
        policy: PolicyCfg::default(),
    }
}

/// The storm: every fault kind rate-driven, plus one scripted worker
/// panic early enough that a 16-request serve is guaranteed to reach
/// it.  Built twice per test — once to serve, once to replay.
fn storm_plan() -> FaultPlan {
    let mut plan = FaultPlan::new(vec![
        FaultSpec { kind: FaultKind::Draft, rate: 0.25, seed: 11 },
        FaultSpec { kind: FaultKind::Target, rate: 0.15, seed: 13 },
        FaultSpec { kind: FaultKind::Pool, rate: 0.10, seed: 17 },
    ]);
    plan.script(FaultKind::Worker, 5);
    plan
}

/// Counters the serve must report, derived purely from the plan by
/// replaying `draws` fault sets through the engine's documented
/// recovery semantics (`fault_prologue`, DESIGN.md §10).
#[derive(Debug, Default, PartialEq, Eq)]
struct Expected {
    faults_injected: u64,
    draft_fallbacks: u64,
    row_retries: u64,
    rows_failed: u64,
    pool_rebuilds: u64,
}

fn replay(mut plan: FaultPlan, draws: u64) -> Expected {
    let mut e = Expected::default();
    for _ in 0..draws {
        let fs = plan.begin_iteration();
        e.faults_injected += fs.injected;
        if fs.worker {
            // The prologue panics before any other fault in the set
            // takes effect; the armed set is already consumed, so the
            // serving loop's one retry runs clean.
            e.pool_rebuilds += 1;
            continue;
        }
        if let Some(t) = fs.target {
            if t.fails > MAX_TARGET_RETRIES {
                // Persistent: budget exhausted, victim row failed,
                // iteration skipped (so a co-fired draft fault never
                // reaches the draft branch).
                e.row_retries += MAX_TARGET_RETRIES;
                e.rows_failed += 1;
                continue;
            }
            e.row_retries += t.fails;
        }
        if fs.draft {
            // PARD drafts, so every surviving draft fault becomes a
            // fallback (greedy: K=0 commit; sampled: held iteration).
            e.draft_fallbacks += 1;
        }
    }
    e
}

fn run_chaos(sampling: Option<SamplingCfg>) {
    let rt = Runtime::reference(7);
    let prompts = rt.prompts("code").unwrap().prompts;
    let trace =
        build_trace(&prompts, N_REQ, Arrival::Closed, MAX_NEW, 7);

    // Fault-free ground truth on the identical costed clock.
    let mut calm_eng = build_engine(&rt, &cfg(&rt, sampling)).unwrap();
    calm_eng.warmup().unwrap();
    let calm = serve_trace_virtual_costed(calm_eng.as_mut(), &trace,
                                          PASS_S, COL_S)
        .unwrap();
    assert_eq!(calm.completed, N_REQ, "baseline must serve everything");

    // The storm.  (a): no Err, no unwound serve — `unwrap` IS the
    // survival gate.
    let mut plan = storm_plan();
    let mut eng = build_engine(&rt, &cfg(&rt, sampling)).unwrap();
    eng.warmup().unwrap();
    let storm = serve_trace_virtual_costed_with_faults(
        eng.as_mut(), &trace, PASS_S, COL_S, &mut plan)
        .unwrap();

    // (c) every request ends in exactly one typed outcome.
    assert_eq!(storm.outcomes.len(), N_REQ);
    assert_eq!(storm.completed + storm.failed, N_REQ,
               "no request may vanish without a typed outcome");
    assert_eq!(storm.expired, 0, "no deadlines in this trace");

    // (b) + (c): request-by-request against the fault-free run.
    let mut n_failed = 0u64;
    for (i, pair) in
        storm.outcomes.iter().zip(&calm.outcomes).enumerate()
    {
        match pair {
            (RequestOutcome::Completed { tokens, .. },
             RequestOutcome::Completed { tokens: want, .. }) => {
                assert_eq!(tokens, want,
                           "request {i}: a non-faulted row must be \
                            bit-identical to the fault-free run");
            }
            (RequestOutcome::Failed { reason }, _) => {
                n_failed += 1;
                assert!(reason.contains("target pass failed"),
                        "request {i}: failure must be typed with its \
                         cause, got `{reason}`");
            }
            other => {
                panic!("request {i}: unexpected outcome pair {other:?}")
            }
        }
    }

    // (c) the storm never leaks KV: pool fully drained.
    let m = eng.metrics();
    assert_eq!(m.kv_blocks_in_use, 0,
               "fault storm must return every KV block");

    // (d) counters match the plan's schedule EXACTLY: replay a fresh
    // clone of the same plan for exactly as many draws as the serve
    // consumed.
    let draws = plan.iteration();
    assert!(draws > 5, "the serve must reach the scripted panic");
    let exp = replay(storm_plan(), draws);
    assert_eq!(m.faults_injected, exp.faults_injected);
    assert_eq!(m.faults_injected, plan.injected(),
               "serving layer must count exactly the plan's faults");
    assert_eq!(m.draft_fallbacks, exp.draft_fallbacks);
    assert_eq!(m.row_retries, exp.row_retries);
    assert_eq!(m.rows_failed, exp.rows_failed);
    assert_eq!(m.pool_rebuilds, exp.pool_rebuilds);
    assert_eq!(exp.pool_rebuilds, 1,
               "exactly the one scripted worker panic");
    assert_eq!(n_failed, exp.rows_failed,
               "typed Failed outcomes must equal the schedule's \
                persistent incidents");
    assert!(m.draft_fallbacks > 0,
            "a 25% draft rate over {draws} draws must fire");
    // (No ordering claim on storm vs calm virtual time: a persistent
    // target incident kills a row EARLY, saving its remaining decode
    // work, so a storm can legitimately finish sooner than a calm
    // serve.  What must hold is that both clocks terminate — held and
    // retried iterations charge wasted pass units.)
    assert!(storm.wall_s > 0.0 && storm.wall_s.is_finite());
}

#[test]
fn chaos_storm_is_lossless_for_survivors_greedy() {
    run_chaos(None);
}

#[test]
fn chaos_storm_is_lossless_for_survivors_sampled() {
    // Sampled draft faults HOLD the iteration instead of committing
    // K=0 (a K=0 commit would consume different per-row rng draws),
    // so bit-identity must hold under temperature sampling too.
    run_chaos(Some(SamplingCfg { temperature: 0.9, top_p: 0.95,
                                 seed: 5 }));
}

/// Deadlines on the batcher's virtual clock: a budget of zero expires
/// every request — queued AND in-flight — with typed outcomes, no
/// leaked KV blocks, and an engine healthy enough to serve the next
/// trace.
#[test]
fn zero_deadline_budget_expires_everything_then_engine_recovers() {
    let rt = Runtime::reference(7);
    let prompts = rt.prompts("code").unwrap().prompts;
    let trace = build_trace(&prompts, N_REQ, Arrival::Closed, MAX_NEW, 7)
        .with_deadline_budget(0.0);

    let mut eng = build_engine(&rt, &cfg(&rt, None)).unwrap();
    eng.warmup().unwrap();
    let stats = serve_trace_virtual_costed(eng.as_mut(), &trace,
                                           PASS_S, COL_S)
        .unwrap();
    // The first wave is admitted at t == deadline (not yet expired,
    // strict >), steps once, and is reaped the moment the costed
    // clock advances; the queue expires with it.
    assert_eq!(stats.expired, N_REQ, "budget 0 must expire the trace");
    assert_eq!(stats.completed, 0);
    assert!(stats
        .outcomes
        .iter()
        .all(|o| matches!(o, RequestOutcome::DeadlineExceeded)));
    assert_eq!(eng.metrics().deadline_exceeded, N_REQ as u64);
    assert_eq!(eng.metrics().kv_blocks_in_use, 0,
               "expired rows must release their blocks immediately");

    // Same engine, no deadlines: everything completes — mass expiry
    // left no wedged slots behind.
    let calm = build_trace(&prompts, N_REQ, Arrival::Closed, MAX_NEW, 7);
    let stats = serve_trace_virtual_costed(eng.as_mut(), &calm,
                                           PASS_S, COL_S)
        .unwrap();
    assert_eq!(stats.completed, N_REQ);
    assert_eq!(eng.metrics().deadline_exceeded, N_REQ as u64,
               "per-event counting: the second serve adds none");
}
