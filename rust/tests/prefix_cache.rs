//! Prefix-sharing pool contract suite (DESIGN.md §7): cross-request
//! block reuse must be invisible to outputs — bit-identical streams
//! with sharing on/off through ALL FIVE engines and on the host fast
//! path at 1/2/8 lanes — while a shared-system-prompt trace admits
//! strictly more concurrent sequences than the same trace without
//! sharing.  Also pins the K=2 window-edge regression (the headroom
//! guard used to hardcode a worst-case K).  Runs in plain
//! `cargo test` with NO artifacts.

use pard::coordinator::batcher::serve_trace_virtual;
use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::coordinator::metrics::Metrics;
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::substrate::workload::{build_shared_prefix_trace, Arrival};
use pard::Runtime;

/// (kind, target, k, batch, kv_blocks, prefix_cache, max_new) — the
/// per-test knobs, bundled so the helper stays clippy-clean.
type Knobs<'a> = (EngineKind, &'a str, usize, usize, Option<usize>,
                  bool, usize);

fn cfg(rt: &Runtime, knobs: Knobs) -> EngineConfig {
    let (kind, target, k, batch, kv_blocks, share, max_new) = knobs;
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new,
        shared_mask: true,
        kv_blocks,
        prefix_cache: share,
        sampling: None,
        policy: PolicyCfg::default(),
    }
}

/// Generate sequentially and return (outputs, final engine metrics).
fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> (Vec<Vec<i32>>, Metrics) {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    let out = generate(e.as_mut(), prompts, c.max_new).unwrap();
    let m = e.metrics().clone();
    (out, m)
}

/// Prompts sharing one 32-token (2-block) system prefix, tails drawn
/// from the task set — built through the workload generator so the
/// trace layer is exercised too.
fn shared_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    let base = rt.prompts("code").unwrap().prompts;
    build_shared_prefix_trace(&base, n, 1, 32, Arrival::Closed, 8, 11)
        .requests
        .into_iter()
        .map(|r| r.prompt)
        .collect()
}

/// The headline identity: enabling the prefix cache must not change a
/// single output token for any engine.  Sequential prompts over one
/// batch slot make every admit after the first a prefix hit (released
/// rows register their blocks), so the on-run really exercises shared
/// blocks — asserted through the hit counter for the engines that
/// share (uncached AR has no cache to share; EAGLE shares memory but
/// recomputes its prefill for the head backlog).
#[test]
fn sharing_preserves_outputs_across_all_five_engines() {
    let rt = Runtime::reference(7);
    let prompts = shared_prompts(&rt, 3);
    for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                 EngineKind::Pard, EngineKind::Eagle] {
        let knobs_off = (kind, "target-l", 4, 1, None, false, 10);
        let knobs_on = (kind, "target-l", 4, 1, None, true, 10);
        let (off, _) = gen(&rt, &cfg(&rt, knobs_off), &prompts);
        let (on, m) = gen(&rt, &cfg(&rt, knobs_on), &prompts);
        assert!(off.iter().all(|o| !o.is_empty()),
                "{kind:?}: baseline generated nothing");
        assert_eq!(off, on, "{kind:?}: prefix sharing changed outputs");
        if kind != EngineKind::Ar {
            assert!(m.prefix_hit_tokens >= 32,
                    "{kind:?}: repeated prefixes must hit the cache \
                     (hit tokens = {})", m.prefix_hit_tokens);
            assert_eq!(m.cow_copies, 0,
                       "{kind:?}: the engine protocol never triggers \
                        copy-on-write");
        }
    }
}

/// Host fast path with sharing enabled stays token-identical to the
/// scalar oracle with sharing enabled, at every worker-pool size —
/// the §8 lane-invariance claim carried over shared block tables.
#[test]
fn host_sharing_matches_oracle_at_1_2_8_lanes() {
    let oracle = Runtime::reference(7);
    let prompts = shared_prompts(&oracle, 3);
    let knobs: Knobs =
        (EngineKind::Pard, "target-m", 4, 2, Some(8), true, 8);
    let want = gen(&oracle, &cfg(&oracle, knobs), &prompts).0;
    for lanes in [1usize, 2, 8] {
        let host = Runtime::host_with_threads(7, Some(lanes));
        let (got, m) = gen(&host, &cfg(&host, knobs), &prompts);
        assert_eq!(want, got,
                   "host sharing diverged at {lanes} lane(s)");
        assert!(m.prefix_hit_tokens > 0,
                "host run must actually share at {lanes} lane(s)");
    }
}

/// The tentpole serving property: on a shared-system-prompt trace over
/// a tight pool, prefix sharing admits STRICTLY more concurrent
/// sequences than the same trace without it (each hit turns two
/// blocks per cache of per-row reservation into shared blocks counted
/// once).
#[test]
fn shared_prefix_trace_admits_more_concurrency() {
    let rt = Runtime::reference(7);
    let base = rt.prompts("code").unwrap().prompts;
    let trace = build_shared_prefix_trace(&base, 6, 1, 32,
                                          Arrival::Closed, 8, 3);
    let run = |share: bool| {
        let c = cfg(&rt, (EngineKind::Pard, "target-m", 4, 4, Some(8),
                          share, 8));
        let mut e = build_engine(&rt, &c).unwrap();
        e.warmup().unwrap();
        let stats = serve_trace_virtual(e.as_mut(), &trace, 1.0).unwrap();
        (stats, e.metrics().clone())
    };
    let (off, off_m) = run(false);
    let (on, on_m) = run(true);
    assert_eq!(off.completed, 6, "baseline must complete the trace");
    assert_eq!(on.completed, 6, "sharing must complete the trace");
    assert!(
        on.peak_occupancy > off.peak_occupancy,
        "sharing must admit more concurrent sequences: peak {} vs {}",
        on.peak_occupancy, off.peak_occupancy
    );
    assert_eq!(off_m.prefix_hit_tokens, 0);
    assert!(on_m.prefix_hit_tokens >= 32 * 2,
            "five repeat admits over two caches must hit repeatedly \
             (hit tokens = {})", on_m.prefix_hit_tokens);
    assert!(on_m.kv_blocks_shared > 0,
            "concurrent rows must actually share blocks");
    // virtual serving must not have polluted wall-clock metrics
    assert_eq!(on_m.wall_s, 0.0);
    assert!(on_m.virtual_s > 0.0);
}

/// K=2 window-edge regression (engine level): with the guard tracking
/// the configured K, a small-K speculative run generates at least as
/// far into the window as the AR+ baseline, and their streams agree on
/// the common prefix (losslessness).  The old hardcoded `2*16 + 2`
/// guard parked the K=2 row ~30 positions early, truncating long
/// generations near S_max.
#[test]
fn k2_generation_reaches_the_window_edge() {
    let rt = Runtime::reference(7);
    // Self-draft PARD (pard-main shares draft-s weights): candidates
    // always match, so only EOS or the window can stop the row.
    let prompts =
        vec![rt.prompts("code").unwrap().prompts[0].prompt.clone()];
    let ar_knobs = (EngineKind::ArPlus, "draft-s", 2, 1, None, false,
                    120);
    let pard_knobs = (EngineKind::Pard, "draft-s", 2, 1, None, false,
                      120);
    let (ar, _) = gen(&rt, &cfg(&rt, ar_knobs), &prompts);
    let (pard, _) = gen(&rt, &cfg(&rt, pard_knobs), &prompts);
    assert!(
        pard[0].len() >= ar[0].len(),
        "K=2 run parked {} positions before the AR+ window edge",
        ar[0].len().saturating_sub(pard[0].len())
    );
    assert_eq!(&pard[0][..ar[0].len()], &ar[0][..],
               "speculative stream must stay lossless to the edge");
}
