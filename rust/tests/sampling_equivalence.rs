//! Distribution-level equivalence suite for stochastic decoding on the
//! deterministic reference backend — runs in plain `cargo test` with NO
//! Python/XLA artifacts.
//!
//! Two properties anchor the tier (DESIGN.md §6):
//!
//! * temperature → 0 identity: with `--temperature 0` every engine's
//!   sampled output is token-identical to greedy decoding — processed
//!   distributions collapse to exact first-max one-hots, so accept
//!   ratios are exactly 1.0 on matches and residuals collapse onto the
//!   target argmax, regardless of the rng draws.
//! * losslessness at t > 0: the accept/residual correction makes
//!   speculative sampled output follow the target distribution.  Its
//!   exact-testable corollaries: AR (full recompute) and AR+ (cached)
//!   consume identical per-sequence draw streams and must agree
//!   token-for-token at ANY temperature, and a self-drafting VSD has
//!   q == p bitwise, so every candidate is accepted with probability
//!   exactly 1.0.
//!
//! All assertions are exact or seed-deterministic — nothing here can
//! flake.

use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind, SamplingCfg};
use pard::coordinator::policy::PolicyCfg;
use pard::coordinator::router::default_draft;
use pard::Runtime;

fn rt() -> Runtime {
    Runtime::reference(7)
}

fn cfg(rt: &Runtime, kind: EngineKind, target: &str, k: usize,
       batch: usize, sampling: Option<SamplingCfg>) -> EngineConfig {
    EngineConfig {
        kind,
        target: target.to_string(),
        draft: default_draft(&rt.manifest, kind, target).unwrap(),
        batch,
        k,
        max_new: 16,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling,
        policy: PolicyCfg::default(),
    }
}

fn samp(temperature: f32, top_p: f32, seed: u64) -> Option<SamplingCfg> {
    Some(SamplingCfg { temperature, top_p, seed })
}

fn gen(rt: &Runtime, c: &EngineConfig, prompts: &[Vec<i32>])
       -> Vec<Vec<i32>> {
    let mut e = build_engine(rt, c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), prompts, c.max_new).unwrap()
}

fn some_prompts(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
    rt.prompts("code")
        .unwrap()
        .take(n)
        .into_iter()
        .map(|p| p.prompt)
        .collect()
}

/// The acceptance criterion: `--temperature 0` must be token-identical
/// to greedy for ALL FIVE engines — including with a top-p filter
/// configured, which the exact-greedy limit ignores by definition.
#[test]
fn temperature_zero_is_token_identical_to_greedy_for_all_engines() {
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    let base = gen(&rt,
                   &cfg(&rt, EngineKind::ArPlus, "target-l", 4, 1, None),
                   &prompts);
    assert!(base.iter().all(|o| !o.is_empty()), "base generated nothing");
    for kind in [EngineKind::Ar, EngineKind::ArPlus, EngineKind::Vsd,
                 EngineKind::Pard, EngineKind::Eagle] {
        for sampling in [samp(0.0, 1.0, 5), samp(0.0, 0.5, 99)] {
            let out = gen(&rt,
                          &cfg(&rt, kind, "target-l", 4, 1, sampling),
                          &prompts);
            assert_eq!(base, out,
                       "{kind:?} sampled at t=0 ({sampling:?}) \
                        diverged from greedy");
        }
    }
}

/// AR and AR+ sample the same per-sequence draw stream (one draw per
/// generated token, streams keyed by admission ordinal), and the
/// reference backend is bit-exact across call shapes — so cached and
/// full-recompute sampled decoding must agree EXACTLY at any
/// temperature, with and without a nucleus filter.
#[test]
fn sampled_ar_uncached_matches_ar_plus_exactly() {
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    for sampling in [samp(0.8, 1.0, 11), samp(1.2, 0.9, 3)] {
        let a = gen(&rt,
                    &cfg(&rt, EngineKind::Ar, "target-m", 4, 1, sampling),
                    &prompts);
        let b = gen(&rt,
                    &cfg(&rt, EngineKind::ArPlus, "target-m", 4, 1,
                         sampling),
                    &prompts);
        assert_eq!(a, b,
                   "sampled KV-cached decode must equal full recompute \
                    ({sampling:?})");
    }
}

/// draft == target ⇒ q == p bitwise at every position (same weights,
/// same committed content, per-position-independent compute), so the
/// accept ratio is exactly 1.0: a sampled self-draft must never
/// residual-resample and must accept every candidate, committing K+1
/// tokens per iteration — the stochastic mirror of the greedy
/// accept-everything test.
#[test]
fn self_draft_vsd_sampled_accepts_every_candidate() {
    let rt = rt();
    let prompts = some_prompts(&rt, 2);
    let mut c = cfg(&rt, EngineKind::Vsd, "draft-s", 4, 1,
                    samp(0.7, 1.0, 13));
    c.draft = Some("draft-s".to_string());
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, c.max_new).unwrap();
    let m = e.metrics();
    assert!(m.generated > 0);
    assert_eq!(m.residual_resamples, 0,
               "q == p must never reject a candidate");
    assert_eq!(m.k_alpha(4), 1.0, "self-draft must accept everything");
    assert!(m.bonus_samples > 0,
            "full acceptance must commit bonus samples");
    assert!(m.tokens_per_iter() > 3.0,
            "accept-all should commit ~K+1/iter, got {}",
            m.tokens_per_iter());
}

/// Same (seed, temperature) ⇒ identical sampled output run-to-run;
/// changing the seed must actually change what is sampled.
#[test]
fn sampled_output_is_seed_deterministic() {
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 4, 1,
                samp(0.9, 1.0, 21));
    let a = gen(&rt, &c, &prompts);
    let b = gen(&rt, &c, &prompts);
    assert_eq!(a, b, "same seed must reproduce sampled output exactly");
    let other = gen(&rt,
                    &cfg(&rt, EngineKind::Pard, "target-m", 4, 1,
                         samp(0.9, 1.0, 22)),
                    &prompts);
    assert_ne!(a, other, "a different seed must sample differently");
}

/// Per-sequence rng streams are keyed by FCFS admission ordinal, not by
/// slot: sampled output must be invariant to batch size, exactly like
/// the greedy suite's batch-invariance property.
#[test]
fn batch_size_does_not_change_sampled_outputs() {
    let rt = rt();
    let prompts = some_prompts(&rt, 6);
    let sampling = samp(0.8, 1.0, 2);
    let base = gen(&rt,
                   &cfg(&rt, EngineKind::Pard, "target-m", 4, 1,
                        sampling),
                   &prompts);
    for bs in [2usize, 4] {
        let out = gen(&rt,
                      &cfg(&rt, EngineKind::Pard, "target-m", 4, bs,
                           sampling),
                      &prompts);
        assert_eq!(base, out, "PARD sampled batch={bs} changed outputs");
    }
}

/// A real draft at t=1 must exercise BOTH stochastic verify outcomes:
/// every verify row ends in exactly one residual resample or one bonus
/// sample, so their sum bounds the iteration count from below, and a
/// disagreeing draft must get rejected at least occasionally.
#[test]
fn stochastic_verify_counters_accumulate_under_sampling() {
    let rt = rt();
    let prompts = some_prompts(&rt, 3);
    let c = cfg(&rt, EngineKind::Pard, "target-m", 4, 1,
                samp(1.0, 1.0, 17));
    let mut e = build_engine(&rt, &c).unwrap();
    e.warmup().unwrap();
    generate(e.as_mut(), &prompts, c.max_new).unwrap();
    let m = e.metrics();
    assert!(m.generated > 0);
    assert!(m.residual_resamples > 0,
            "a non-self draft at t=1 must see rejections");
    assert!(m.residual_resamples + m.bonus_samples >= m.iterations,
            "every verify row ends in a residual or a bonus commit");
}
