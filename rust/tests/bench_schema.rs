//! Bench-subsystem smoke tests: `pard bench`'s report must (a) be
//! producible with no artifacts, (b) round-trip through the in-repo
//! JSON parser (`substrate::json`), and (c) keep the stable
//! `pard-bench-hotpath/v1` schema later PRs regress against
//! (DESIGN.md §Perf).

use pard::report::bench::{hotpath_report, write_report, BenchOpts,
                          BENCH_SCHEMA};
use pard::substrate::json::Json;

fn smoke_report() -> Json {
    // Tiny sweep: one K, batch 1, two prompts, oracle on — seconds of
    // runtime, exercising every schema field.
    hotpath_report(&BenchOpts::smoke()).unwrap()
}

#[test]
fn report_round_trips_through_repo_json_parser() {
    let report = smoke_report();
    let path = std::env::temp_dir()
        .join(format!("pard_bench_smoke_{}.json", std::process::id()));
    write_report(&path, &report).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let parsed = Json::parse(text.trim()).unwrap();
    assert_eq!(parsed, report, "serialize/parse must round-trip");
}

#[test]
fn schema_v1_fields_are_stable() {
    let report = smoke_report();
    assert_eq!(report.get("schema").unwrap().as_str(),
               Some(BENCH_SCHEMA));
    assert_eq!(report.get("backend").unwrap().as_str(), Some("host"));
    for key in ["threads", "seed", "task", "target", "n_prompts",
                "max_new", "sweep", "runs", "serving_prefix",
                "policy_mixed", "robustness", "quant", "oracle",
                "host_vs_reference"] {
        assert!(report.get(key).is_some(), "missing top-level `{key}`");
    }
    assert!(report.get("threads").unwrap().as_f64().unwrap() >= 1.0,
            "worker-pool size must be recorded");

    let runs = report.get("runs").unwrap().as_arr().unwrap();
    // AR+ once, VSD/PARD/EAGLE once per swept K (smoke: one K, batch 1).
    assert_eq!(runs.len(), 4, "smoke sweep must have 4 cells");
    let engines: Vec<&str> = runs
        .iter()
        .map(|r| r.get("engine").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(engines, ["AR+", "VSD", "PARD", "EAGLE"]);
    for run in runs {
        for key in ["engine", "k", "batch", "tokens_per_s",
                    "tokens_per_iter", "mean_accept_len", "fwd_s",
                    "commit_s", "fwd_ops", "kv", "policy", "draft_s",
                    "verify_s", "prefill_s", "wall_s", "generated",
                    "iterations", "speedup_vs_ar_plus"] {
            assert!(run.get(key).is_some(),
                    "run missing field `{key}`");
        }
        // speculation-policy record (additive v1 fields): the sweep
        // pins fixed mode, so no dual-mode activity can appear
        let pol = run.get("policy").unwrap();
        for key in ["mode", "k_hist", "mode_switches",
                    "dual_mode_iters", "work_pass_units",
                    "work_col_units"] {
            assert!(pol.get(key).is_some(),
                    "policy missing field `{key}`");
        }
        assert_eq!(pol.get("mode").unwrap().as_str(), Some("fixed"));
        assert_eq!(pol.get("mode_switches").unwrap().as_f64(),
                   Some(0.0), "fixed sweeps never switch modes");
        assert!(pol.get("work_pass_units").unwrap().as_f64().unwrap()
                > 0.0,
                "every engine must charge forward-pass work units");
        assert!(run.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0,
                "every cell must have measured throughput");
        assert!(run.get("generated").unwrap().as_f64().unwrap() > 0.0);
        // paged-KV pool stats (additive v1 fields): all three present,
        // peak occupancy positive (every engine allocates blocks), no
        // admission stalls in a closed-batch sweep, and the gauge
        // bounded by its peak
        let kv = run.get("kv").unwrap();
        for key in ["blocks_in_use", "peak_blocks", "admission_stalls",
                    "prefix_hit_tokens", "blocks_shared", "cow_copies"] {
            assert!(kv.get(key).is_some(), "kv missing field `{key}`");
        }
        // the closed-batch sweep runs with the prefix cache off
        assert_eq!(kv.get("prefix_hit_tokens").unwrap().as_f64(),
                   Some(0.0), "sweep cells never share prefixes");
        let kv_peak = kv.get("peak_blocks").unwrap().as_f64().unwrap();
        assert!(kv_peak > 0.0, "engines must record pool occupancy");
        assert!(kv.get("blocks_in_use").unwrap().as_f64().unwrap()
                <= kv_peak,
                "gauge cannot exceed its own peak");
        assert_eq!(kv.get("admission_stalls").unwrap().as_f64(),
                   Some(0.0),
                   "closed-batch eval never stalls admission");
        // per-op fwd breakdown: all six phases present, and populated
        // on the host backend (every engine runs host fwd calls)
        let ops = run.get("fwd_ops").unwrap();
        let mut total = 0.0;
        for key in ["gather_s", "qkv_s", "attn_s", "wo_s", "mlp_s",
                    "logits_s"] {
            total += ops.get(key).unwrap().as_f64().unwrap();
        }
        assert!(total > 0.0, "host rows must carry a fwd_ops breakdown");
        assert!(total <= run.get("fwd_s").unwrap().as_f64().unwrap()
                + 1e-9,
                "fwd_ops must be bounded by fwd_s");
    }

    // The AR+ baseline's speedup over itself is exactly 1.
    let ar = &runs[0];
    assert_eq!(ar.get("k").unwrap(), &Json::Null,
               "AR+ never drafts: k must be null");
    let sp = ar.get("speedup_vs_ar_plus").unwrap().as_f64().unwrap();
    assert!((sp - 1.0).abs() < 1e-9, "AR+ vs itself must be 1.0");
    assert_eq!(ar.get("mean_accept_len").unwrap().as_f64(), Some(0.0),
               "AR+ accepts nothing (it never drafts)");
}

#[test]
fn serving_prefix_section_shows_the_hit_rate_win() {
    let report = smoke_report();
    let sp = report.get("serving_prefix").unwrap();
    for key in ["engine", "k", "batch", "kv_blocks", "n_requests",
                "shared_prefixes", "prefix_len", "rows"] {
        assert!(sp.get(key).is_some(),
                "serving_prefix missing field `{key}`");
    }
    let rows = sp.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2, "one row per prefix-cache setting");
    let f = |r: &pard::substrate::json::Json, k: &str| {
        r.get(k).unwrap().as_f64().unwrap()
    };
    let (off, on) = (&rows[0], &rows[1]);
    assert_eq!(off.get("prefix_cache"), Some(&Json::Bool(false)));
    assert_eq!(on.get("prefix_cache"), Some(&Json::Bool(true)));
    assert_eq!(f(off, "completed"), f(on, "completed"),
               "both settings must serve the whole trace");
    assert_eq!(f(off, "prefix_hit_tokens"), 0.0);
    assert!(f(on, "prefix_hit_tokens") > 0.0,
            "the shared-prefix trace must hit the cache");
    assert!(f(on, "peak_occupancy") >= f(off, "peak_occupancy"),
            "sharing must not reduce concurrency");
}

#[test]
fn policy_mixed_section_reports_all_three_policies() {
    let report = smoke_report();
    let pm = report.get("policy_mixed").unwrap();
    for key in ["engine", "batch", "n_requests", "max_new", "pass_s",
                "col_s", "rows"] {
        assert!(pm.get(key).is_some(),
                "policy_mixed missing field `{key}`");
    }
    let rows = pm.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 3, "fixed-k2, fixed-k16, adaptive");
    let labels: Vec<&str> = rows
        .iter()
        .map(|r| r.get("policy").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(labels, ["fixed-k2", "fixed-k16", "adaptive"]);
    let f = |r: &Json, k: &str| r.get(k).unwrap().as_f64().unwrap();
    let completed = f(&rows[0], "completed");
    for r in rows {
        for key in ["policy", "k", "completed", "generated",
                    "tokens_per_s", "virtual_s", "k_hist",
                    "mode_switches", "dual_mode_iters"] {
            assert!(r.get(key).is_some(),
                    "policy_mixed row missing field `{key}`");
        }
        assert!(f(r, "tokens_per_s") > 0.0,
                "costed-clock throughput must be measured");
        assert_eq!(f(r, "completed"), completed,
                   "every policy must serve the whole mixed trace");
    }
}

#[test]
fn serving_chaos_section_degrades_gracefully_with_rate() {
    let report = smoke_report();
    let chaos = report
        .get("robustness")
        .unwrap()
        .get("serving_chaos")
        .unwrap();
    for key in ["engine", "k", "batch", "n_requests", "max_new",
                "pass_s", "col_s", "rows"] {
        assert!(chaos.get(key).is_some(),
                "serving_chaos missing field `{key}`");
    }
    let rows = chaos.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 3, "one row per fault rate");
    let f = |r: &Json, k: &str| r.get(k).unwrap().as_f64().unwrap();
    let n_req = f(chaos, "n_requests");
    for r in rows {
        for key in ["rate", "completed", "failed", "generated",
                    "tokens_per_s", "virtual_s", "faults_injected",
                    "draft_fallbacks", "row_retries", "rows_failed",
                    "pool_rebuilds", "kv_blocks_at_drain"] {
            assert!(r.get(key).is_some(),
                    "serving_chaos row missing field `{key}`");
        }
        assert_eq!(f(r, "completed") + f(r, "failed"), n_req,
                   "every request must end typed: completed or failed");
        assert_eq!(f(r, "kv_blocks_at_drain"), 0.0,
                   "fault storms must not leak KV blocks");
    }
    let calm = &rows[0];
    assert_eq!(f(calm, "rate"), 0.0);
    assert_eq!(f(calm, "faults_injected"), 0.0,
               "a rate-0 plan must be pass-through");
    assert_eq!(f(calm, "failed"), 0.0);
    // the storm rows actually fired, and every row's costed clock
    // terminated (held/retried iterations charge wasted pass units,
    // so fault storms cost time instead of deadlocking it)
    assert!(f(&rows[2], "faults_injected") > 0.0,
            "a 30% storm over a full serve must fire");
    for r in rows {
        let v = f(r, "virtual_s");
        assert!(v > 0.0 && v.is_finite(), "virtual_s {v}");
    }
}

#[test]
fn quant_section_reports_probe_bytes_and_deltas() {
    let report = smoke_report();
    let q = report.get("quant").unwrap();
    assert_eq!(q.get("backend").unwrap().as_str(), Some("host-q8"));
    for key in ["backend", "probe", "weight_bytes", "k", "n_prompts",
                "max_new", "runs", "deltas"] {
        assert!(q.get(key).is_some(), "quant missing field `{key}`");
    }
    let f = |j: &Json, k: &str| j.get(k).unwrap().as_f64().unwrap();

    // The bounded-error contract as a number in the trajectory: q8
    // logits differ from f32 (it is a lossy representation) but stay
    // small next to the logit magnitudes themselves.
    let probe = q.get("probe").unwrap();
    let err = f(probe, "max_abs_logit_err");
    assert!(err > 0.0, "q8 must actually differ from f32");
    assert!(err < 0.5, "per-logit q8 error out of contract: {err}");
    assert!(f(probe, "max_abs_logit") > err,
            "error must be small relative to the logits");

    // Weight-bytes ledger: int8 codes + f32 per-panel scales land the
    // compression ratio strictly between 3x and 5x (exactly 4x minus
    // scale overhead), matching the Table 6 bytes argument.
    let wb = q.get("weight_bytes").unwrap();
    let ratio = f(wb, "f32_over_q8");
    assert!(ratio > 3.0 && ratio < 5.0,
            "f32/q8 weight bytes ratio out of range: {ratio}");
    assert!(f(wb.get("q8").unwrap(), "total")
            < f(wb.get("f32").unwrap(), "total"));

    // Eval rows: {AR+, PARD} x {host, host-q8}, every cell measured.
    let runs = q.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 4, "2 engines x 2 backends");
    for r in runs {
        for key in ["engine", "backend", "k", "batch", "tokens_per_s",
                    "mean_accept_len", "generated"] {
            assert!(r.get(key).is_some(), "quant run missing `{key}`");
        }
        assert!(f(r, "tokens_per_s") > 0.0 && f(r, "generated") > 0.0,
                "every quant cell must be measured");
    }
    let deltas = q.get("deltas").unwrap().as_arr().unwrap();
    assert_eq!(deltas.len(), 2, "one delta row per engine");
    for d in deltas {
        assert!(f(d, "tps_ratio_q8_vs_f32") > 0.0);
        assert!(d.get("accept_len_delta").is_some());
    }
}

#[test]
fn compare_quant_is_clean_against_itself() {
    use pard::report::bench::{compare_quant, COMPARE_TOL};
    let report = smoke_report();
    let (has_quant, lines) =
        compare_quant(&report, &report, COMPARE_TOL);
    assert!(has_quant, "a fresh report carries the quant section");
    assert!(lines.is_empty(),
            "a report can never regress against itself");
}

#[test]
fn oracle_section_mirrors_sweep_and_reports_speedups() {
    let report = smoke_report();
    let oracle = report.get("oracle").unwrap();
    assert_eq!(oracle.get("backend").unwrap().as_str(),
               Some("reference"));
    let oruns = oracle.get("runs").unwrap().as_arr().unwrap();
    let runs = report.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(oruns.len(), runs.len(),
               "oracle must replay the identical sweep");

    let hvr = report.get("host_vs_reference").unwrap();
    let per = hvr.get("per_run").unwrap().as_arr().unwrap();
    assert_eq!(per.len(), runs.len());
    // Timing-dependent magnitudes are asserted by `pard bench` users,
    // not unit tests — here only well-formedness: positive and finite.
    let geo = hvr.get("geomean").unwrap().as_f64().unwrap();
    let min = hvr.get("min").unwrap().as_f64().unwrap();
    assert!(geo > 0.0 && geo.is_finite());
    assert!(min > 0.0 && min.is_finite());
}

#[test]
fn report_compares_clean_against_itself() {
    use pard::report::bench::{compare_reports, COMPARE_TOL};
    let report = smoke_report();
    assert!(compare_reports(&report, &report, COMPARE_TOL).is_empty(),
            "a report can never regress against itself");
}

#[test]
fn no_oracle_flag_drops_comparison_sections() {
    let mut o = BenchOpts::smoke();
    o.oracle = false;
    let report = hotpath_report(&o).unwrap();
    assert!(report.get("oracle").is_none());
    assert!(report.get("host_vs_reference").is_none());
    assert!(report.get("runs").is_some());
}
