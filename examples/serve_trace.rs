//! Online serving demo: Poisson arrivals into the continuous batcher
//! (the vLLM-analogue path behind Tables 3/4), comparing PARD against
//! the AR baseline under the same trace.  Runs on the deterministic
//! virtual clock (one simulated second per decode iteration scaled to
//! 10ms), so the printed latencies and stall counts are reproducible
//! — no 200µs idle spins, no host-scheduling noise.
//!
//!     cargo run --release --example serve_trace [rate] [n]

use std::path::Path;

use anyhow::Result;
use pard::coordinator::batcher::serve_trace_virtual;
use pard::coordinator::engines::{build_engine, EngineConfig, EngineKind};
use pard::substrate::workload::{build_trace, Arrival};
use pard::Runtime;

fn main() -> Result<()> {
    let rate: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6.0);
    let n: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let rt = Runtime::load(Path::new("artifacts"))?;
    let prompts = rt.prompts("gsm")?.prompts;
    let trace = build_trace(&prompts, n, Arrival::Poisson { rate }, 48, 7);
    println!("trace: {n} requests, Poisson λ={rate}/s, batch slots = 4\n");

    for kind in [EngineKind::ArPlus, EngineKind::Pard] {
        let cfg = EngineConfig {
            kind,
            target: "target-l".into(),
            draft: match kind {
                EngineKind::Pard => Some(rt.manifest.main_pard.clone()),
                _ => None,
            },
            batch: 4,
            k: 8,
            max_new: 48,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
        };
        let mut engine = build_engine(&rt, &cfg)?;
        engine.warmup()?;
        // 10ms of virtual time per decode iteration: deterministic
        // latencies under the same arrival trace.
        let stats = serve_trace_virtual(engine.as_mut(), &trace, 0.01)?;
        println!(
            "{:<5} completed={:<3} throughput={:>7.1} tok/s  \
             latency p50={:.3}s p95={:.3}s  occupancy={:.2}",
            kind.label(),
            stats.completed,
            stats.throughput_tps,
            stats.latency_p50_s,
            stats.latency_p95_s,
            stats.mean_occupancy
        );
        println!(
            "      peak occupancy={}  admission stalls={}",
            stats.peak_occupancy, stats.admission_stalls
        );
    }
    Ok(())
}
