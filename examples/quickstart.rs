//! Quickstart: load the AOT artifacts, accelerate `target-l` with the
//! PARD-adapted draft, and print generated text + throughput.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;

use anyhow::Result;
use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;

    // PARD: target-independent — the same adapted draft serves the whole
    // family; swap `target` for any of draft-s/target-m/target-l/target-xl.
    let cfg = EngineConfig {
        kind: EngineKind::Pard,
        target: "target-l".into(),
        draft: Some(rt.manifest.main_pard.clone()),
        batch: 1,
        k: 8,
        max_new: 48,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
    };
    let mut engine = build_engine(&rt, &cfg)?;
    engine.warmup()?; // compile executables outside the timed region

    let prompts: Vec<Vec<i32>> = rt
        .prompts("code")?
        .take(4)
        .into_iter()
        .map(|p| p.prompt)
        .collect();

    let outputs = generate(engine.as_mut(), &prompts, cfg.max_new)?;

    for (p, o) in prompts.iter().zip(&outputs) {
        println!("prompt: {}", rt.tokenizer.detok(p));
        println!("  gen:  {}\n", rt.tokenizer.detok(o));
    }
    let m = engine.metrics();
    println!("PARD: {:.1} tok/s  ({:.2} tokens/iteration, 1-α={:.2})",
             m.tps(), m.tokens_per_iter(), m.k_alpha(1));
    Ok(())
}
