//! Target independence (paper Table 2 / Fig. 2): ONE PARD draft
//! accelerates every member of the model family, including the draft's
//! own base model — no per-target retraining, unlike EAGLE/Medusa.
//!
//!     cargo run --release --example target_independence

use std::path::Path;

use anyhow::Result;
use pard::coordinator::router::FAMILY_TARGETS;
use pard::report::{cell, RunScale};
use pard::coordinator::engines::EngineKind;
use pard::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let scale = RunScale { n_prompts: 6, max_new: 48 };
    println!("one PARD draft ({}) vs the whole family:\n",
             rt.manifest.main_pard);
    println!("{:<10} {:>10} {:>10} {:>9} {:>12}", "target", "AR+ tok/s",
             "PARD tok/s", "speedup", "tokens/iter");
    for target in FAMILY_TARGETS {
        let base =
            cell(&rt, EngineKind::ArPlus, target, "code", 8, 1, scale)?;
        let pard =
            cell(&rt, EngineKind::Pard, target, "code", 8, 1, scale)?;
        println!("{:<10} {:>10.1} {:>10.1} {:>8.2}x {:>12.2}", target,
                 base.tps(), pard.tps(), pard.tps() / base.tps(),
                 pard.metrics.tokens_per_iter());
    }
    println!("\nEAGLE (target-dependent) by contrast only reaches:");
    for target in FAMILY_TARGETS {
        let ok = rt
            .manifest
            .models
            .contains_key(&format!("eagle-{target}"));
        println!("  {target:<10} {}", if ok { "trained head ✓" }
                 else { "NO head — would need a new training run" });
    }
    Ok(())
}
