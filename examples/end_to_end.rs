//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all layers compose on a real small workload: the build-time-
//! trained SynLlama family (L2 JAX models over the L1 Pallas attention
//! kernel, AOT-compiled to PJRT executables) served by the L3 rust
//! coordinator with continuous batching across all three task suites,
//! reporting latency/throughput/acceptance per engine — and asserting
//! the lossless property (speculative outputs == AR+ outputs) on every
//! request.
//!
//!     cargo run --release --example end_to_end

use std::path::Path;

use anyhow::Result;
use pard::coordinator::batcher::serve_trace;
use pard::coordinator::engines::{build_engine, generate, EngineConfig,
                                 EngineKind};
use pard::runtime::Backend;
use pard::substrate::workload::{build_trace, Arrival};
use pard::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    let target = "target-l";
    let max_new = 48;
    println!("== PARD end-to-end driver ==");
    println!("target {target} ({} params), draft {} ({} params)\n",
             rt.model(target)?.n_params(),
             rt.manifest.main_pard,
             rt.model(&rt.manifest.main_pard)?.n_params());

    // ---- 1. lossless check on every task --------------------------------
    let mut checked = 0usize;
    for task in ["code", "gsm", "math"] {
        let prompts: Vec<Vec<i32>> = rt
            .prompts(task)?
            .take(6)
            .into_iter()
            .map(|p| p.prompt)
            .collect();
        let mk = |kind: EngineKind| EngineConfig {
            kind,
            target: target.into(),
            draft: match kind {
                EngineKind::Pard => Some(rt.manifest.main_pard.clone()),
                _ => None,
            },
            batch: 1,
            k: 8,
            max_new,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
        };
        let mut base = build_engine(&rt, &mk(EngineKind::ArPlus))?;
        base.warmup()?;
        let ref_out = generate(base.as_mut(), &prompts, max_new)?;
        let mut pard = build_engine(&rt, &mk(EngineKind::Pard))?;
        pard.warmup()?;
        let pard_out = generate(pard.as_mut(), &prompts, max_new)?;
        anyhow::ensure!(ref_out == pard_out,
                        "LOSSLESS VIOLATION on task {task}");
        checked += prompts.len();
        let (bm, pm) = (base.metrics().clone(), pard.metrics().clone());
        println!("[{task:<4}] lossless ✓ ({} prompts)  AR+ {:.1} tok/s → \
                  PARD {:.1} tok/s ({:.2}x, {:.2} tok/iter, 1-α {:.2})",
                 prompts.len(), bm.tps(), pm.tps(), pm.tps() / bm.tps(),
                 pm.tokens_per_iter(), pm.k_alpha(1));
    }
    println!("\nlossless property held on {checked} requests\n");

    // ---- 2. batched online serving --------------------------------------
    println!("== continuous batching, mixed workload, Poisson λ=8/s ==");
    let mut prompts = Vec::new();
    for task in ["code", "gsm", "math"] {
        prompts.extend(rt.prompts(task)?.take(8));
    }
    let trace = build_trace(&prompts, 24, Arrival::Poisson { rate: 8.0 },
                            max_new, 11);
    for kind in [EngineKind::ArPlus, EngineKind::Vsd, EngineKind::Pard] {
        let cfg = EngineConfig {
            kind,
            target: target.into(),
            draft: match kind {
                EngineKind::Pard => Some(rt.manifest.main_pard.clone()),
                EngineKind::Vsd => Some("draft-s".into()),
                _ => None,
            },
            batch: 4,
            k: 8,
            max_new,
            shared_mask: true,
            kv_blocks: None,
            prefix_cache: false,
            sampling: None,
        };
        let mut engine = build_engine(&rt, &cfg)?;
        engine.warmup()?;
        let stats = serve_trace(engine.as_mut(), &trace)?;
        println!("{:<5} {:>3} reqs  {:>7.1} tok/s  latency p50 {:.3}s \
                  p95 {:.3}s  occupancy {:.2}",
                 kind.label(), stats.completed, stats.throughput_tps,
                 stats.latency_p50_s, stats.latency_p95_s,
                 stats.mean_occupancy);
    }

    // ---- 3. sample output ------------------------------------------------
    let cfg = EngineConfig {
        kind: EngineKind::Pard,
        target: target.into(),
        draft: Some(rt.manifest.main_pard.clone()),
        batch: 1,
        k: 8,
        max_new,
        shared_mask: true,
        kv_blocks: None,
        prefix_cache: false,
        sampling: None,
    };
    let mut engine = build_engine(&rt, &cfg)?;
    engine.warmup()?;
    let p = rt.prompts("gsm")?.take(1);
    let out = generate(engine.as_mut(), &[p[0].prompt.clone()], max_new)?;
    println!("\nsample ({}):", p[0].task);
    println!("  Q: {}", rt.tokenizer.detok(&p[0].prompt));
    println!("  A: {}", rt.tokenizer.detok(&out[0]));
    println!("\nend_to_end OK");
    Ok(())
}
